"""Self-healing training (train.guard): the escalation ladder end to end —
in-graph non-finite skip (bitwise no-op), loss-spike skip, per-scene
bisection quarantine, last_good rollback, typed abort — plus the ISSUE's
acceptance equivalence: a poisoned guarded run's final params are bitwise
identical to a clean run on the healthy work alone."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import CheckpointManager
from repro.data import scenes
from repro.models import pointcloud as pc
from repro.serve import compile_network
from repro.train import (AdamWConfig, GuardConfig, GuardedPointCloudTrainer,
                         LossSpikeDetector, PointCloudTrainConfig,
                         PointCloudTrainer, TrainAbortError, init_opt_state,
                         labeled_batch, labeled_tensor, segmentation_loss)
from repro.train import faults as tf
from repro.train.guard import guarded_apply_updates
from repro.train.pointcloud import scene_features

EXTENT = (32, 28, 16)
N_CLASSES = 6


def _setup(batch=3, seed=0, guard=None, **kw):
    sb = scenes.scene_batch(seed=seed, batch=batch, kind="indoor",
                            extent=EXTENT, labels=True, n_classes=N_CLASSES)
    net = pc.tiny_segnet(in_channels=4, n_classes=N_CLASSES, width=8, depth=3)
    session = compile_network(net, sb[0].layout, batch=batch)
    st, lab = labeled_batch(sb, session.layout)
    trainer = session.compile_train(guard=guard or GuardConfig(), **kw)
    return sb, session, trainer, st, lab


def _tree_bytes(tree):
    return [np.asarray(x).tobytes() for x in jax.tree.leaves(tree)]


def _clone_session(session, batch):
    net = session.net
    return compile_network(net, session.layout, batch=batch,
                           params=session.params)


# -- rung 1: in-graph non-finite skip is a bitwise no-op ----------------------

@pytest.mark.parametrize("value", [float("nan"), float("inf")])
def test_nonfinite_batch_is_bitwise_noop(value):
    # single-scene batch: bisection has nothing to split, pure skip path
    _, session, tr, st, lab = _setup(batch=1)
    tr.step(st, lab)                      # one clean commit first
    p_bytes = _tree_bytes(session.params)
    o_bytes = _tree_bytes(tr.opt_state)
    m = tr.step(tf.poison_nonfinite(st, rows=(0,), value=value), lab)
    assert m["step_ok"] == 0.0
    assert _tree_bytes(session.params) == p_bytes    # bitwise unchanged
    assert _tree_bytes(tr.opt_state) == o_bytes      # step counter included
    r = tr.last_report
    assert r.action == "skipped" and r.nonfinite and not r.committed
    assert r.quarantined == [0]           # the only scene IS the fault
    assert tr.counters["nonfinite_steps"] == 1
    assert tr.counters["steps_skipped"] == 1


def test_guarded_equals_plain_on_clean_batches():
    _, s1, guarded, st, lab = _setup(batch=3)
    s2 = _clone_session(s1, batch=3)
    plain = s2.compile_train()
    assert isinstance(plain, PointCloudTrainer)
    assert not isinstance(plain, GuardedPointCloudTrainer)
    for _ in range(3):
        m_g = guarded.step(st, lab)
        m_p = plain.step(st, lab)
    assert m_g["loss"] == m_p["loss"]
    assert _tree_bytes(s1.params) == _tree_bytes(s2.params)
    assert guarded.counters["steps_ok"] == 3


# -- rung 2: loss-spike skip --------------------------------------------------

def test_label_poison_trips_spike_detector_not_nan():
    # out-of-range labels are clipped to a wrong-but-finite loss
    # (segmentation_loss doc) — only the spike detector can catch them.
    # Train the baseline down first (~0.75 at lr 2e-2): everything-wrong
    # label poison then costs ~2.4, ~3x the recent median.
    g = GuardConfig(spike_window=6, spike_factor=1.8, spike_min_history=4,
                    bisect=False, rollback_after=100)
    tcfg = PointCloudTrainConfig(opt=AdamWConfig(lr=2e-2, warmup_steps=2,
                                                 total_steps=100))
    sb = scenes.scene_batch(seed=0, batch=2, kind="indoor", extent=EXTENT,
                            labels=True, n_classes=N_CLASSES)
    net = pc.tiny_segnet(in_channels=4, n_classes=N_CLASSES, width=8,
                         depth=3)
    session = compile_network(net, sb[0].layout, batch=2)
    st, lab = labeled_batch(sb, session.layout)
    tr = session.compile_train(tcfg, guard=g)
    for _ in range(15):
        tr.step(st, lab)
    assert tr.last_report.ok
    p_bytes = _tree_bytes(session.params)
    bad_lab = tf.poison_labels(lab, rows=range(int(st.count)), value=10 ** 6)
    m = tr.step(st, bad_lab)
    assert np.isfinite(m["loss"]) and m["step_ok"] == 1.0   # finite, "valid"
    r = tr.last_report
    assert r.spike and not r.nonfinite and r.action == "skipped"
    assert _tree_bytes(session.params) == p_bytes
    assert tr.counters["spikes"] == 1
    # healthy training continues and the baseline is uncorrupted
    m = tr.step(st, lab)
    assert tr.last_report.ok and np.isfinite(m["loss"])


def test_spike_detector_unit():
    d = LossSpikeDetector(window=4, factor=10.0, min_history=3, floor=1e-3)
    assert not d.is_spike(1e9)            # disarmed: no history
    for v in (1.0, 1.1, 0.9):
        d.record(v)
    assert d.is_spike(50.0) and not d.is_spike(5.0)
    for v in (2.0, 2.0, 2.0, 2.0):        # ring evicts the old baseline
        d.record(v)
    assert not d.is_spike(15.0) and d.is_spike(25.0)
    d.reset()
    assert not d.is_spike(1e9)


# -- rung 3: bisection quarantine + the acceptance equivalence ----------------

def test_bisection_quarantines_poisoned_scene_only():
    _, session, tr, st, lab = _setup(batch=4, seed=2)
    tr.step(st, lab)
    m = tr.step(tf.poison_scene_nonfinite(st, 2), lab)
    assert m["step_ok"] == 0.0
    r = tr.last_report
    assert r.action == "bisected" and r.nonfinite
    assert r.quarantined == [2]
    committed = sorted(i for grp in r.committed for i in grp)
    assert committed == [0, 1, 3]         # every innocent scene trained
    c = tr.counters
    assert c["bisections"] == 1 and c["scenes_quarantined"] == 1
    assert c["sub_steps_committed"] == len(r.committed)


def test_poisoned_run_bitwise_equals_clean_run_on_healthy_work():
    """The ISSUE acceptance criterion (skip path): a guarded run fed
    NaN-poisoned batches finishes with params bitwise identical to a clean
    PLAIN trainer run over exactly the committed work (full healthy
    batches + the bisection sub-batches the reports recorded)."""
    batch = 3
    sb, s1, tr, st, lab = _setup(batch=batch, seed=3)
    s2 = _clone_session(s1, batch=batch)

    poisoned_at = {1: 1, 3: 0}            # step index -> poisoned scene
    reports = []
    for i in range(5):
        x = (tf.poison_scene_nonfinite(st, poisoned_at[i])
             if i in poisoned_at else st)
        tr.step(x, lab)
        reports.append(tr.last_report)

    # replay the committed groups through a clean plain trainer
    clean = s2.compile_train()
    clouds = [(sc.coords, scene_features(sc), sc.labels) for sc in sb]
    for r in reports:
        for grp in r.committed:
            if grp is None:
                clean.step(st, lab)
            else:
                sst, slab = labeled_tensor([clouds[i] for i in grp],
                                           s2.layout)
                clean.step(sst, slab)

    assert _tree_bytes(s1.params) == _tree_bytes(s2.params)
    assert _tree_bytes(tr.opt_state) == _tree_bytes(clean.opt_state)
    assert tr.counters["scenes_quarantined"] == 2
    assert tr.counters["steps_ok"] == 3


# -- rung 4+5: rollback and typed abort ---------------------------------------

def test_rollback_restores_last_good(tmp_path):
    g = GuardConfig(rollback_after=2, bisect=True)
    mgr = CheckpointManager(str(tmp_path / "ck"), async_save=False)
    _, session, tr, st, lab = _setup(batch=1, guard=g, ckpt=mgr)
    tr.step(st, lab)
    good = tr.save(mark_good=True)        # the rollback anchor
    good_bytes = _tree_bytes(session.params)
    tr.step(st, lab)                      # drift past the anchor
    bad = tf.poison_nonfinite(st, rows=(0,))
    tr.step(bad, lab)                     # consec_bad = 1
    tr.step(bad, lab)                     # consec_bad = 2 -> rollback
    r = tr.last_report
    assert r.action == "rolled_back" and r.rollback_to == good
    assert _tree_bytes(session.params) == good_bytes
    assert int(tr.opt_state.step) == good
    assert tr.counters["rollbacks"] == 1
    # training continues from the anchor
    tr.step(st, lab)
    assert tr.last_report.ok


def test_abort_without_checkpoint_manager():
    g = GuardConfig(rollback_after=2, bisect=False)
    _, _, tr, st, lab = _setup(batch=1, guard=g)
    bad = tf.poison_nonfinite(st, rows=(0,))
    tr.step(bad, lab)
    with pytest.raises(TrainAbortError) as ei:
        tr.step(bad, lab)
    assert ei.value.report is not None
    assert ei.value.counters["nonfinite_steps"] == 2


def test_abort_after_max_rollbacks(tmp_path):
    g = GuardConfig(rollback_after=1, max_rollbacks=1, bisect=False)
    mgr = CheckpointManager(str(tmp_path / "ck"), async_save=False)
    _, _, tr, st, lab = _setup(batch=1, guard=g, ckpt=mgr)
    tr.step(st, lab)
    tr.save(mark_good=True)
    bad = tf.poison_nonfinite(st, rows=(0,))
    tr.step(bad, lab)                     # rollback #1
    assert tr.last_report.action == "rolled_back"
    with pytest.raises(TrainAbortError) as ei:
        tr.step(bad, lab)                 # rollback budget exhausted
    assert "max_rollbacks" in str(ei.value)


# -- checkpoint cadence, last_good advancement, resume ------------------------

def test_auto_checkpoint_cadence_and_last_good_lag(tmp_path):
    g = GuardConfig(ckpt_every=2, last_good_after=2)
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=10, async_save=False)
    _, _, tr, st, lab = _setup(batch=2, guard=g, ckpt=mgr)
    for i in range(4):
        tr.step(st, lab)
    mgr.wait()
    assert mgr.complete_steps() == [2, 4]
    # step-2 save was followed by 2 healthy steps -> blessed; step-4 not yet
    assert mgr.last_good_step() == 2
    assert tr.counters["checkpoint_saves"] == 2
    tr.step(st, lab)
    tr.step(st, lab)
    assert mgr.last_good_step() == 4      # now blessed too


def test_bad_steps_do_not_advance_last_good(tmp_path):
    g = GuardConfig(ckpt_every=1, last_good_after=2, bisect=False,
                    rollback_after=100)
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=10, async_save=False)
    _, _, tr, st, lab = _setup(batch=1, guard=g, ckpt=mgr)
    tr.step(st, lab)                      # save @1, pending
    bad = tf.poison_nonfinite(st, rows=(0,))
    tr.step(bad, lab)                     # skipped: must not bless step 1
    assert mgr.last_good_step() is None
    tr.step(st, lab)                      # healthy; save @2 now pending
    tr.step(st, lab)
    tr.step(st, lab)
    assert mgr.last_good_step() == 2


def test_resume_walks_past_corrupt_latest(tmp_path):
    """The ISSUE acceptance criterion (fallback path): resume restores the
    newest VERIFYING checkpoint when the latest is corrupt, and counters
    record the checksum failure."""
    d = str(tmp_path / "ck")
    g = GuardConfig(ckpt_every=1, last_good_after=1)
    mgr = CheckpointManager(d, keep=10, async_save=False)
    _, s1, tr, st, lab = _setup(batch=2, guard=g, ckpt=mgr)
    p0 = s1.params
    snap = {}
    for i in range(3):
        tr.step(st, lab)
        mgr.wait()
        snap[int(tr.opt_state.step)] = _tree_bytes(s1.params)
    tf.corrupt_checkpoint(d, 3, mode="flip")

    # a fresh process: new session (same init), resume from the directory
    net = s1.net
    s2 = compile_network(net, s1.layout, batch=2, params=p0)
    mgr2 = CheckpointManager(d, async_save=False)
    tr2 = s2.compile_train(guard=True, ckpt=mgr2, resume=True)
    assert int(tr2.opt_state.step) == 2   # 3 is corrupt, 2 verifies
    assert _tree_bytes(s2.params) == snap[2]
    assert tr2.counters["checksum_failures"] == 1
    assert tr2.counters["last_good_step"] == 2
    # and training continues bitwise on the same trajectory as the
    # uninterrupted run: one step from the restored state == step 3's params
    tr2.step(st, lab)
    assert _tree_bytes(s2.params) == snap[3]


def test_resume_empty_directory_is_noop(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), async_save=False)
    _, s1, tr, st, lab = _setup(batch=1, guard=True, ckpt=mgr)
    assert tr.resume() is None
    assert int(tr.opt_state.step) == 0


# -- satellite: zero-supervised-voxel loss pin --------------------------------

def test_segmentation_loss_zero_supervised_voxels_is_finite_zero():
    # all-ignore labels: Σw = 0; the maximum(Σw, 1) denominator must give an
    # exact 0.0 (not 0/0 = NaN) with finite all-zero logit grads, both paths
    logits = jnp.asarray(np.random.default_rng(0).normal(
        size=(16, N_CLASSES)).astype(np.float32))
    labels = jnp.full((16,), -1, jnp.int32)

    def run(seg):
        (l, a), g = jax.value_and_grad(
            lambda lg: segmentation_loss(lg, labels, seg=seg),
            has_aux=True)(logits)
        return float(l), float(a), np.asarray(g)

    sid = jnp.zeros((16,), jnp.int32)
    seg = (sid, jnp.asarray([0]), jnp.asarray([16]), 1)
    for s in (None, seg):
        loss, acc, grads = run(s)
        assert loss == 0.0 and acc == 0.0
        assert np.all(grads == 0.0) and np.all(np.isfinite(grads))


def test_guarded_step_commits_zero_supervised_batch():
    # the guard must never have to catch this case: it is a healthy commit
    _, session, tr, st, lab = _setup(batch=2)
    m = tr.step(st, jnp.full_like(lab, -1))
    assert m["step_ok"] == 1.0 and m["loss"] == 0.0
    assert tr.last_report.ok


# -- satellite: deterministic mirror of the property (test_property.py) ------

def _rand_tree(seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(5, 3)).astype(np.float32)
                             * scale),
            "b": {"w": jnp.asarray(rng.normal(size=(7,)).astype(np.float32)
                                   * scale)}}


@pytest.mark.parametrize("poison,where", [
    (float("nan"), "a"), (float("inf"), "b"),
    (float("-inf"), "a"), (float("nan"), "loss")])
def test_guarded_apply_updates_never_writes_nonfinite(poison, where):
    cfg = AdamWConfig(warmup_steps=1, total_steps=10)
    params = _rand_tree(0)
    opt = init_opt_state(params, cfg)
    grads = _rand_tree(1, scale=1e-2)
    loss = jnp.asarray(1.5)
    if where == "a":
        grads["a"] = grads["a"].at[2, 1].set(poison)
    elif where == "b":
        grads["b"]["w"] = grads["b"]["w"].at[0].set(poison)
    else:
        loss = jnp.asarray(poison)
    p_bytes = _tree_bytes(params)
    o_bytes = _tree_bytes(opt)
    new_p, new_o, m = jax.jit(
        lambda p, g, o, l: guarded_apply_updates(p, g, o, cfg, loss=l)
    )(params, grads, opt, loss)
    assert float(m["step_ok"]) == 0.0
    assert _tree_bytes(new_p) == p_bytes      # bitwise passthrough
    assert _tree_bytes(new_o) == o_bytes
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(new_p))


def test_guarded_apply_updates_finite_path_applies():
    cfg = AdamWConfig(warmup_steps=1, total_steps=10)
    params = _rand_tree(0)
    opt = init_opt_state(params, cfg)
    grads = _rand_tree(1, scale=1e-2)
    new_p, new_o, m = guarded_apply_updates(params, grads, opt, cfg,
                                            loss=jnp.asarray(1.5))
    assert float(m["step_ok"]) == 1.0
    assert int(new_o.step) == 1
    assert _tree_bytes(new_p) != _tree_bytes(params)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(new_p))
