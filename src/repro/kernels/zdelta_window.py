"""Hierarchical z-delta search kernels — TPU-native forms of Spira §5.2.

The GPU algorithm's locality story (anchor binary search + ≤K−1 contiguous
probes staying in cache lines) is restaged for the TPU memory hierarchy.
Two generations live here:

``zdelta_window_search`` (per-group windows, the PR-1 kernel, kept as the
DMA-count baseline for ``benchmarks/bench_indexing``):

  Phase A (XLA, cheap): per (output tile, anchor group), one `searchsorted`
    for the tile's *first* anchor query gives the HBM window start.
  Phase B (Pallas): grid (n_tiles, K²) — K² independent window DMAs per
    output tile, each group's bm×K queries resolved against its window with
    a (bm, W) broadcast-compare per member: O(bm·W) compares.

``zdelta_superwindow_search`` (the current engine):

  Phase A (XLA): ONE `searchsorted` per output tile — the window base is the
    insertion point of the tile's smallest query (first row + smallest
    anchor). All G anchor groups of the tile share it.
  Phase B (Pallas): grid (n_tiles,) — ONE superwindow DMA per output tile
    covering every anchor group (SpOctA-style shared staging across
    neighbor offsets). Per-group offsets are resolved *inside* VMEM: a
    batched branchless binary search finds all (bm, G) anchor lower bounds
    in log2(SW) gather-compare steps, then the K−1 remaining members of
    each group reuse the z-delta two-pointer: the cursor advances only on a
    hit (sound by the Integer Property, see core/zdelta.py), so each member
    costs one gather-compare instead of a (bm, W) broadcast. Compares drop
    from O(bm·W) per (group, member) to O(bm·(log SW + K)) per group.

Both report matches beyond the static window via overflow counters so the
caller can fall back to the XLA path for those tiles (none in practice once
the tuner's ``plan_superwindow`` sizes SW exactly).

So vs the paper: binary-search count drops |Vq|·K³ → |Vq|·K² (batched in
Phase B), HBM round trips drop K²× (one DMA per tile), and the probe works
on VMEM-resident contiguous data — the paper's two wins plus the shared
staging win, expressed with DMA + vector compares instead of cache lines.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.voxel import CoordSet, pad_value


def _kernel(starts_ref,            # scalar-prefetch int32 [n_tiles, K2]
            anchors_ref,           # scalar-prefetch [K2] packed anchors
            out_block_ref,         # (1, bm) packed outputs (VMEM)
            arr_hbm,               # full sorted input array (ANY/HBM)
            m_ref,                 # out: (bm, 1, K) int32
            ovf_ref,               # out: (1, 1) int32 overflow counter
            win_ref,               # scratch VMEM (W,)
            sem,                   # DMA semaphore
            *, zstep, K, W, n, pad):
    t = pl.program_id(0)
    g = pl.program_id(1)
    start = jnp.clip(starts_ref[t, g], 0, n - W)
    cp = pltpu.make_async_copy(arr_hbm.at[pl.ds(start, W)], win_ref, sem)
    cp.start()
    cp.wait()
    win = win_ref[...]                                   # (W,) sorted slice
    rows = out_block_ref[0, :]
    q0 = rows + anchors_ref[g]                           # (bm,) anchor queries
    # PAD sentinel rows are masked to -1 by the caller regardless; their
    # (wrapped / near-int-max) queries must not trip the overflow counter.
    real = rows != pad
    last_val = win[W - 1]
    ovf = jnp.zeros((), jnp.int32)
    for r in range(K):
        q = q0 + r * zstep
        eq = win[None, :] == q[:, None]                  # (bm, W) vector compare
        hit = eq.any(axis=1)
        idx = jnp.argmax(eq, axis=1).astype(jnp.int32) + start
        m_ref[:, 0, r] = jnp.where(hit, idx, -1)
        # a query above the window's last element may match beyond the DMA'd
        # slice — count so the host can fall back for this tile.
        ovf += ((q > last_val) & (start + W < n) & real).sum().astype(jnp.int32)
    ovf_ref[0, 0] = ovf


@functools.partial(jax.jit, static_argnames=("zstep", "K", "W", "bm", "interpret"))
def zdelta_window_search(
    inputs: CoordSet,
    outputs: CoordSet,
    packed_anchors: jax.Array,   # [K2]
    zstep: int,
    *,
    K: int,
    W: int = 512,
    bm: int = 128,
    interpret: bool = False,
):
    """Returns (kernel map [M, K³], overflow counts [n_tiles, K²])."""
    from repro.core.zdelta import _count_search
    _count_search()
    arr = inputs.packed
    n = arr.shape[0]
    mcap = outputs.packed.shape[0]
    assert mcap % bm == 0, (mcap, bm)
    assert n >= W, f"input capacity {n} must be >= window {W}"
    n_tiles = mcap // bm
    k2 = K * K

    # Phase A: one searchsorted per (tile, group) for the tile's first query.
    out2d = outputs.packed.reshape(n_tiles, bm)
    starts = jnp.searchsorted(
        arr, out2d[:, 0][:, None] + packed_anchors[None, :], side="left"
    ).astype(jnp.int32)                                  # [n_tiles, K2]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles, k2),
        in_specs=[
            pl.BlockSpec((1, bm), lambda t, g, *_: (t, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((bm, 1, K), lambda t, g, *_: (t, g, 0)),
            pl.BlockSpec((1, 1), lambda t, g, *_: (t, g)),
        ],
        scratch_shapes=[pltpu.VMEM((W,), arr.dtype), pltpu.SemaphoreType.DMA],
    )
    m3, ovf = pl.pallas_call(
        functools.partial(_kernel, zstep=int(zstep), K=K, W=W, n=n,
                          pad=pad_value(arr.dtype)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((mcap, k2, K), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, k2), jnp.int32),
        ],
        interpret=interpret,
    )(starts, packed_anchors, out2d, arr)

    m = m3.reshape(mcap, K * K * K)
    pad = pad_value(arr.dtype)
    m = jnp.where((outputs.packed != pad)[:, None], m, -1)
    return m, ovf


# ---------------------------------------------------------------------------
# superwindow kernel: one DMA per output tile, all anchor groups share it
# ---------------------------------------------------------------------------

def _super_kernel(starts_ref,           # scalar-prefetch int32 [n_tiles]
                  out_block_ref,        # (1, bm) packed outputs (VMEM)
                  anchors_ref,          # (G,) packed anchors (VMEM)
                  arr_hbm,              # full sorted input array (ANY/HBM)
                  m_ref,                # out: (bm, G, K) int32
                  ovf_ref,              # out: (1, G) int32 overflow counters
                  win_ref,              # scratch VMEM (SW,)
                  sem,                  # DMA semaphore
                  *, zstep, K, G, SW, n, pad, nbits):
    t = pl.program_id(0)
    base = jnp.clip(starts_ref[t], 0, n - SW)
    cp = pltpu.make_async_copy(arr_hbm.at[pl.ds(base, SW)], win_ref, sem)
    cp.start()
    cp.wait()
    win = win_ref[...]                                   # (SW,) sorted slice
    rows = out_block_ref[0, :]                           # (bm,)
    real = (rows != pad)[:, None]                        # (bm, 1)
    q = rows[:, None] + anchors_ref[...][None, :]        # (bm, G) anchors
    # Batched branchless binary search: pos = |{w in win : w < q}| for all
    # (bm, G) anchor queries at once — log2(SW) gather+compare rounds,
    # instead of a (bm, SW) broadcast-compare per query.
    pos = jnp.zeros(q.shape, jnp.int32)
    for sbit in reversed(range(nbits)):
        cand = pos + (1 << sbit)
        vals = win[jnp.clip(cand - 1, 0, SW - 1)]
        pos = jnp.where((cand <= SW) & (vals < q), cand, pos)
    # Two-pointer member resolve: the Integer Property guarantees no packed
    # value lies strictly between consecutive member queries q + r·zstep and
    # q + (r+1)·zstep, so the cursor advances only on a hit.
    last_val = win[SW - 1]
    ovf = jnp.zeros((1, G), jnp.int32)
    cursor = pos
    zs = jnp.asarray(zstep, q.dtype)
    for r in range(K):
        cand = win[jnp.clip(cursor, 0, SW - 1)]
        hit = (cand == q) & (cursor < SW) & real
        m_ref[:, :, r] = jnp.where(hit, cursor + base, -1)
        ovf += ((q > last_val) & real).sum(axis=0, dtype=jnp.int32)[None, :]
        cursor = cursor + hit.astype(jnp.int32)
        q = q + zs
    # a window running to the array end cannot miss matches past its edge.
    ovf_ref[...] = jnp.where(base + SW < n, ovf, 0)


@functools.partial(jax.jit, static_argnames=("zstep", "K", "W", "bm", "interpret"))
def zdelta_superwindow_search(
    inputs: CoordSet,
    outputs: CoordSet,
    packed_anchors: jax.Array,   # [G] — K² for a full search, ⌈K²/2⌉+… for
                                 # the §5.4 half-search (any ascending subset)
    zstep: int,
    *,
    K: int,
    W: int = 2048,
    bm: int = 128,
    interpret: bool = False,
):
    """Returns (kernel map [M, G·K], overflow counts [n_tiles, G]).

    One superwindow DMA per output tile (vs K² in
    :func:`zdelta_window_search`); columns follow the order of
    ``packed_anchors`` (group g, member r → column g·K + r).
    """
    from repro.core.zdelta import _count_search
    _count_search()
    arr = inputs.packed
    n = arr.shape[0]
    mcap = outputs.packed.shape[0]
    G = packed_anchors.shape[0]
    assert mcap % bm == 0, (mcap, bm)
    assert n >= W, f"input capacity {n} must be >= superwindow {W}"
    n_tiles = mcap // bm
    nbits = max(1, int(np.ceil(np.log2(W))))

    # Phase A: one searchsorted per tile. Anchors ascend (offset_grid is
    # row-major lex), so the tile's smallest query is row 0 + anchors[0] and
    # every query of the tile has its lower bound at or after this base.
    out2d = outputs.packed.reshape(n_tiles, bm)
    starts = jnp.searchsorted(
        arr, out2d[:, 0] + packed_anchors[0], side="left").astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, bm), lambda t, *_: (t, 0)),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((bm, G, K), lambda t, *_: (t, 0, 0)),
            pl.BlockSpec((1, G), lambda t, *_: (t, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((W,), arr.dtype), pltpu.SemaphoreType.DMA],
    )
    m3, ovf = pl.pallas_call(
        functools.partial(_super_kernel, zstep=int(zstep), K=K, G=G, SW=W,
                          n=n, pad=pad_value(arr.dtype), nbits=nbits),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((mcap, G, K), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, G), jnp.int32),
        ],
        interpret=interpret,
    )(starts, out2d, packed_anchors, arr)

    m = m3.reshape(mcap, G * K)
    pad = pad_value(arr.dtype)
    m = jnp.where((outputs.packed != pad)[:, None], m, -1)
    return m, ovf
