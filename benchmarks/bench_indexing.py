"""Indexing trajectory bench: map-construction latency per engine plus a
sort/search/DMA work breakdown, persisted to BENCH_indexing.json so the
perf history accumulates across PRs (mirror of BENCH_dataflow.json).

Engines measured on a small 3-layer net (submanifold K3, downsample K3,
submanifold K5 — the shape mix real nets use):

* ``zdelta``            — XLA search, default downsample ("auto": merge on
                          TPU, sort fallback off-TPU)
* ``zdelta_merge``      — XLA search, single-sort merge downsample forced
                          (the TPU plan pipeline, timed wherever we run)
* ``zdelta_resort``     — XLA search, sort-per-level downsample (pre-PR-2)
* ``zdelta_sym``        — §5.4 half-search + mirror fill on submanifold
                          layers (tuner-gated in production: the mirror
                          scatter loses on CPU XLA, wins where scatter is
                          cheap — both sides recorded here)
* ``zdelta_pallas``     — superwindow kernel (1 DMA/tile; interpreter off-TPU)
* ``zdelta_pallas_window`` — PR-1 per-group kernel (K² DMAs/tile)
* ``bsearch`` / ``hash``   — the paper's baselines

Off-TPU the Pallas rows time the interpreter (relative algorithmic cost
only — see benchmarks/common.py); the work counters (sorts per plan, search
count, DMA count/bytes) are host-independent and are the quantities the
acceptance criteria track: exactly one full sort per plan, one window DMA
per output tile.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.core import (SpConvSpec, build_network_plan, plan_levels,
                        symmetry_anchor_count)
from repro.data import scenes as sc_mod
from .common import emit, scene_set, timeit, us

RESULTS = os.path.join(os.path.dirname(__file__), "..", "BENCH_indexing.json")

# pallas interpreter rows are slow off-TPU; keep them to the smallest scene
PALLAS_SCENES = 1


def _specs(symmetry=False):
    return (
        SpConvSpec("l0_sub3", 4, 8, K=3, m_in=0, m_out=0, symmetry=symmetry),
        SpConvSpec("l1_down", 8, 16, K=3, m_in=0, m_out=1, symmetry=symmetry),
        SpConvSpec("l2_sub5", 16, 16, K=5, m_in=1, m_out=1, symmetry=symmetry),
    )


def _work_model(specs, mcaps, bm=128):
    """Host-independent work counters per engine variant."""
    levels = plan_levels(specs)
    n_down = len([m for m in levels if m > 0])
    searches = {"full": 0, "sym": 0}
    dma = {"window": 0, "superwindow": 0}
    for s in specs:
        mcap = mcaps[s.m_out]
        n_tiles = (mcap + bm - 1) // bm
        g_full, g_sym = s.K ** 2, symmetry_anchor_count(s.K)
        searches["full"] += mcap * g_full
        searches["sym"] += mcap * (g_sym if s.submanifold else g_full)
        dma["window"] += n_tiles * g_full
        dma["superwindow"] += n_tiles
    return {
        "sorts_per_plan": {"merge": 1, "resort": 1 + n_down},
        "anchor_searches": searches,
        "window_dmas": dma,
    }


def run():
    rows, records = [], []
    for si, (name, sc) in enumerate(scene_set()):
        packed = jnp.asarray(sc_mod.pack_scene(sc))
        variants = [
            ("zdelta", dict(engine="zdelta")),
            ("zdelta_merge", dict(engine="zdelta",
                                  downsample_method="merge")),
            ("zdelta_resort", dict(engine="zdelta",
                                   downsample_method="sort")),
            ("zdelta_sym", dict(engine="zdelta", symmetry=True)),
            ("bsearch", dict(engine="bsearch")),
            ("hash", dict(engine="hash")),
        ]
        if si < PALLAS_SCENES:
            variants += [
                ("zdelta_pallas", dict(engine="zdelta_pallas")),
                ("zdelta_pallas_window",
                 dict(engine="zdelta_pallas_window")),
            ]
        timings = {}
        mcaps = None
        for vname, kw in variants:
            kw = dict(kw)
            specs = _specs(symmetry=kw.pop("symmetry", False))
            fn = jax.jit(lambda p, kw=kw, specs=specs: build_network_plan(
                p, specs=specs, layout=sc.layout, **kw))
            dt = timeit(fn, packed, repeats=3, warmup=1)
            timings[vname] = dt
            if mcaps is None:
                plan = fn(packed)
                mcaps = {m: plan.coords[m].capacity for m in plan.coords}
        work = _work_model(_specs(), mcaps)
        for vname, dt in timings.items():
            derived = []
            if vname == "zdelta_merge":
                derived.append(f"speedup_vs_resort="
                               f"{timings['zdelta_resort'] / dt:.2f}")
            if vname == "zdelta_sym":
                derived.append(f"speedup_vs_full="
                               f"{timings['zdelta'] / dt:.2f}")
            if vname == "zdelta_pallas" and "zdelta_pallas_window" in timings:
                derived.append(
                    "dma_per_plan="
                    f"{work['window_dmas']['superwindow']}"
                    f";dma_per_plan_window={work['window_dmas']['window']}")
            rows.append((f"indexing/{name}/{vname}", us(dt),
                         ";".join(derived)))
        records.append({
            "scene": name,
            "timings_us": {k: us(v) for k, v in timings.items()},
            "work": work,
        })

    rec = {
        "host_backend": jax.default_backend(),
        "note": ("pallas rows run the interpreter off-TPU; work counters "
                 "(sorts/searches/DMAs) are the device-independent claims"),
        "scenes": records,
    }
    hist = []
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            hist = json.load(f)
            if not isinstance(hist, list):
                hist = [hist]
    hist.append(rec)
    with open(RESULTS, "w") as f:
        json.dump(hist, f, indent=1)
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
